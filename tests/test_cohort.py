"""Cohort execution runtime: gather/scatter primitives, ExecutionConfig
plumbing, cohort-vs-dense step equivalence, eval_every thinning, the async
max_concurrency cap, and the SGDTrainer remainder fix."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ExecutionConfig, SchedulerConfig
from repro.core.selection import CohortSelection, cohort_from_mask, cohort_from_scores, get_strategy
from repro.data import make_federated_classification
from repro.fl import FLConfig, api, phases, run_federated
from repro.fl.cohort import cohort_indices, tree_scatter, tree_take
from repro.models.mlp import init_mlp, mlp_loss


@pytest.fixture(scope="module")
def small_ds():
    return make_federated_classification(
        n_clients=16, n_classes=4, n_features=20,
        samples_per_client_range=(40, 60), dirichlet_alpha=50.0,
        client_shift=0.05, class_sep=5.0, seed=3,
    )


# ---------------------------------------------------------------------------
# ExecutionConfig: validation, flat kwargs, cohort resolution
# ---------------------------------------------------------------------------


def test_execution_config_validation():
    with pytest.raises(ValueError, match="cohort_size"):
        ExecutionConfig(cohort_size=-1)
    with pytest.raises(ValueError, match="eval_every"):
        ExecutionConfig(eval_every=0)
    with pytest.raises(ValueError, match="max_concurrency"):
        SchedulerConfig(max_concurrency=-1)


def test_execution_flat_and_nested_kwargs():
    cfg = FLConfig(cohort_size=32, eval_every=4, max_concurrency=8)
    assert cfg.execution == ExecutionConfig(cohort_size=32, eval_every=4)
    assert cfg.cohort_size == 32 and cfg.eval_every == 4
    assert cfg.scheduler.max_concurrency == 8 and cfg.max_concurrency == 8
    cfg2 = FLConfig(execution=ExecutionConfig(cohort_size=32, eval_every=4))
    assert cfg2.execution == cfg.execution
    assert FLConfig().execution == ExecutionConfig()  # default: dense-equivalent
    with pytest.raises(ValueError, match="not both"):
        FLConfig(execution=ExecutionConfig(cohort_size=4), eval_every=2)


def test_resolved_cohort():
    assert ExecutionConfig().resolved_cohort(100) == 100
    assert ExecutionConfig(cohort_size=16).resolved_cohort(100) == 16
    assert ExecutionConfig(cohort_size=200).resolved_cohort(100) == 100


def test_pipeline_from_config_wires_eval_every_and_remainder():
    pipe = api.pipeline_from_config(FLConfig(eval_every=3, remainder="pad"))
    assert pipe.evaluator.eval_every == 3
    assert pipe.trainer.remainder == "pad"
    with pytest.raises(ValueError, match="remainder"):
        FLConfig(remainder="truncate")


# ---------------------------------------------------------------------------
# cohort index API (core.selection) + gather/scatter primitives (fl.cohort)
# ---------------------------------------------------------------------------


def test_cohort_from_mask_orders_and_masks():
    mask = jnp.asarray([False, True, False, True, True, False])
    sel = cohort_from_mask(mask, 4)
    assert isinstance(sel, CohortSelection)
    # selected ids ascending first, then unselected padding ascending
    assert np.asarray(sel.idx).tolist() == [1, 3, 4, 0]
    assert np.asarray(sel.valid).tolist() == [True, True, True, False]
    # truncation keeps the first K selected ids
    sel2 = cohort_from_mask(mask, 2)
    assert np.asarray(sel2.idx).tolist() == [1, 3]
    assert np.asarray(sel2.valid).all()
    assert np.asarray(cohort_indices(mask, 4)).tolist() == [1, 3, 4, 0]


def test_cohort_from_scores_matches_mask_form():
    scores = jnp.asarray([0.1, 5.0, 3.0, 0.2])
    sel = cohort_from_scores(scores, jnp.ones(4, bool), jnp.asarray(2), 3)
    assert np.asarray(sel.idx).tolist()[:2] == [1, 2]
    assert np.asarray(sel.valid).tolist() == [True, True, False]


def test_select_cohort_default_matches_mask():
    strat = get_strategy("fedavg", fraction=0.5)
    obs_mask = np.random.default_rng(0)
    m = jnp.zeros(8)
    from repro.core.selection import ClientObservations

    obs = ClientObservations(m, m, jnp.ones(8), jnp.ones(8))
    rng = jax.random.PRNGKey(4)
    mask = np.asarray(strat.select(obs, jnp.asarray(1), rng))
    sel = strat.select_cohort(obs, jnp.asarray(1), rng, 4)
    assert sorted(np.asarray(sel.idx)[np.asarray(sel.valid)].tolist()) == np.nonzero(mask)[0].tolist()


def test_tree_take_scatter_roundtrip_and_none():
    tree = {"w": jnp.arange(12.0).reshape(6, 2), "n": jnp.arange(6, dtype=jnp.int32)}
    idx = jnp.asarray([4, 1])
    taken = tree_take(tree, idx)
    assert np.asarray(taken["w"]).tolist() == [[8.0, 9.0], [2.0, 3.0]]
    # scatter-back of the gathered lanes is the identity
    back = tree_scatter(tree, idx, taken)
    for leaf, orig in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(leaf), np.asarray(orig))
    # modified lanes land only at idx
    mod = jax.tree.map(lambda l: l + 1, taken)
    out = tree_scatter(tree, idx, mod)
    assert np.asarray(out["n"]).tolist() == [0, 2, 2, 3, 5, 5]
    # None passes through (stateless local params / lossless residuals)
    assert tree_take(None, idx) is None and tree_scatter(None, idx, None) is None
    # drop mode: out-of-range sentinel lanes touch nothing (async scheduler)
    idx_drop = jnp.asarray([4, 6])
    out2 = tree_scatter(tree, idx_drop, mod, mode="drop")
    assert np.asarray(out2["n"]).tolist() == [0, 1, 2, 3, 5, 5]


# ---------------------------------------------------------------------------
# cohort-vs-dense equivalence + O(K) execution end-to-end
# ---------------------------------------------------------------------------


def _init_state(ds, g0, select, stateful=True):
    c = ds.n_clients
    loc0 = (
        jax.tree.map(lambda l: jnp.broadcast_to(l, (c,) + l.shape), g0)
        if stateful
        else None
    )
    return api.RoundState(
        global_params=g0, local_params=loc0,
        accuracy=jnp.zeros((c,)), select=select,
        pms=jnp.full((c,), len(g0), jnp.int32), rng=jax.random.PRNGKey(7),
        participation=jnp.zeros((c,), jnp.int32),
        loss=jnp.zeros((c,)), update_norm=jnp.zeros((c,)),
    )


@pytest.mark.parametrize("personalization", ["ft", "none"])
def test_cohort_step_matches_dense_when_selection_fits(small_ds, personalization):
    """Gathered (K,) lanes compute the dense path's numbers exactly when the
    cohort covers the selection (the tentpole's bit-identity claim at K<C)."""
    c = small_ds.n_clients
    cfg = FLConfig(strategy="fedavg", personalization=personalization,
                   fraction=0.25, rounds=3, epochs=1)
    env = api.build_env(small_ds, 0)
    pipe = api.pipeline_from_config(cfg)
    g0 = init_mlp(jax.random.PRNGKey(0), small_ds.n_features, small_ds.n_classes)
    sel0 = jnp.asarray([True] * 4 + [False] * (c - 4))
    stateful = pipe.personalizer.stateful
    dense = jax.jit(api.build_round_step(env, pipe))
    cohort = jax.jit(api.build_round_step(env, pipe, ExecutionConfig(cohort_size=4)))
    sd = _init_state(small_ds, g0, sel0, stateful)
    sc = _init_state(small_ds, g0, sel0, stateful)
    for t in range(3):
        sd, od = dense(sd, jnp.asarray(t))
        sc, oc = cohort(sc, jnp.asarray(t))
        np.testing.assert_array_equal(np.asarray(od["selected"]), np.asarray(oc["selected"]))
        np.testing.assert_array_equal(np.asarray(od["acc"]), np.asarray(oc["acc"]))
        np.testing.assert_array_equal(
            np.asarray(od["wire_per_client"]), np.asarray(oc["wire_per_client"])
        )


def test_cohort_run_end_to_end_stateless(small_ds):
    """cohort_size bounds the trained lanes; the stateless personalizer
    drops the (C, P) local carry; history records the lane count."""
    h = run_federated(
        small_ds,
        FLConfig(strategy="fedavg", personalization="none", fraction=0.25,
                 rounds=4, epochs=1, cohort_size=4),
    )
    assert np.isfinite(h.accuracy_mean).all()
    np.testing.assert_array_equal(h.in_flight, 4)
    # steady-state cohorts (after the truncated warm start) hold 4 clients
    assert (h.selected[1:].sum(axis=1) == 4).all()


def test_cohort_run_with_lossy_codec_and_dld(small_ds):
    """Cohort execution composes with EF residual state + partial sharing."""
    h = run_federated(
        small_ds,
        FLConfig(strategy="acsp-fl", personalization="dld", rounds=5, epochs=1,
                 codec="int8", cohort_size=8),
    )
    assert np.isfinite(h.accuracy_mean).all()
    assert h.accuracy_mean[-1] > h.accuracy_mean[0]
    assert (h.selected.sum(axis=1) <= 8).all()


# ---------------------------------------------------------------------------
# eval_every: thinned distributed eval carries last-known accuracy
# ---------------------------------------------------------------------------


def test_eval_every_carries_last_known_accuracy(small_ds):
    kw = dict(strategy="fedavg", personalization="none", fraction=0.5,
              rounds=6, epochs=1)
    every = run_federated(small_ds, FLConfig(**kw))
    thinned = run_federated(small_ds, FLConfig(eval_every=2, **kw))
    acc = thinned.accuracy_per_client
    # skipped rounds repeat the previous row; eval rounds match the
    # every-round run exactly (selection is rng-driven, not accuracy-driven)
    for t in range(6):
        if t % 2 == 0:
            np.testing.assert_array_equal(acc[t], every.accuracy_per_client[t])
        else:
            np.testing.assert_array_equal(acc[t], acc[t - 1])


def test_eval_every_async(small_ds):
    h = run_federated(
        small_ds,
        FLConfig(strategy="fedavg", personalization="none", fraction=1.0,
                 rounds=6, epochs=1, scheduler="async", buffer_k=4,
                 heterogeneity=0.5, eval_every=3),
    )
    assert np.isfinite(h.accuracy_mean).all()
    # between eval events the history rows are carried verbatim
    assert (h.accuracy_per_client[1] == h.accuracy_per_client[0]).all()
    assert (h.accuracy_per_client[2] == h.accuracy_per_client[0]).all()


# ---------------------------------------------------------------------------
# async max_concurrency: at most M_c clients in flight (FedBuff cap)
# ---------------------------------------------------------------------------


def test_async_max_concurrency_caps_in_flight(small_ds):
    m_c = 3
    h = run_federated(
        small_ds,
        FLConfig(strategy="fedavg", personalization="none", fraction=1.0,
                 rounds=10, epochs=1, scheduler="async", buffer_k=2,
                 max_concurrency=m_c, heterogeneity=0.8),
    )
    assert (h.in_flight <= m_c).all()
    assert (h.in_flight >= 1).all()          # the queue never drains
    assert (h.selected.sum(axis=1) <= m_c).all()
    assert np.isfinite(h.accuracy_mean).all()


def test_async_max_concurrency_decoupled_from_selection(small_ds):
    """Selection may want half the population; the slot pool still bounds
    in-flight work (concurrency and selection tunable independently)."""
    h = run_federated(
        small_ds,
        FLConfig(strategy="oort", personalization="none", fraction=0.5,
                 rounds=8, epochs=1, scheduler="async", buffer_k=2,
                 max_concurrency=4, heterogeneity=0.5),
    )
    assert (h.in_flight <= 4).all()
    assert np.isfinite(h.accuracy_mean).all()


def test_async_cohort_size_bounds_slots_when_concurrency_unset(small_ds):
    """The O(K) promise holds in async mode too: with max_concurrency=0,
    ExecutionConfig.cohort_size caps the dispatch-slot pool."""
    h = run_federated(
        small_ds,
        FLConfig(strategy="fedavg", personalization="none", fraction=1.0,
                 rounds=6, epochs=1, scheduler="async", buffer_k=2,
                 cohort_size=5, heterogeneity=0.5),
    )
    assert (h.in_flight <= 5).all()
    assert np.isfinite(h.accuracy_mean).all()


def test_async_default_concurrency_matches_population(small_ds):
    h = run_federated(
        small_ds,
        FLConfig(strategy="fedavg", personalization="none", fraction=1.0,
                 rounds=3, epochs=1, scheduler="async",
                 buffer_k=small_ds.n_clients),
    )
    # M=0 -> C slots: the warm start dispatches everyone
    np.testing.assert_array_equal(h.in_flight, small_ds.n_clients)


# ---------------------------------------------------------------------------
# SGDTrainer remainder: the tiny-client / truncated-tail fix
# ---------------------------------------------------------------------------


def _tiny_client_ds():
    """C=2 slab of 40 slots: client 0 has 3 valid samples, client 1 has 40.
    With batch_size=32 the seed's remainder truncation trains on slots
    [0, 32) only — client 1 silently loses 8 real samples."""
    rng = np.random.default_rng(0)
    c, n, f = 2, 40, 5
    x = rng.normal(size=(c, n, f)).astype(np.float32)
    y = rng.integers(0, 3, size=(c, n)).astype(np.int32)
    m = np.zeros((c, n), bool)
    m[0, :3] = True
    m[1, :] = True
    return x, y, m


@pytest.mark.parametrize("remainder", ["drop", "pad"])
def test_sgd_trainer_three_sample_client_is_finite(remainder):
    x, y, m = _tiny_client_ds()
    trainer = phases.SGDTrainer(epochs=2, batch_size=32, lr=0.1, remainder=remainder)
    g0 = init_mlp(jax.random.PRNGKey(0), 5, 3, hidden=(8,))
    train_model = jax.tree.map(lambda l: jnp.broadcast_to(l, (2,) + l.shape), g0)
    env = phases.RoundEnv(
        x_tr=jnp.asarray(x), y_tr=jnp.asarray(y), m_tr=jnp.asarray(m),
        x_te=jnp.asarray(x), y_te=jnp.asarray(y), m_te=jnp.asarray(m),
        n_samples=jnp.asarray(m.sum(1), jnp.float32), delay=jnp.ones((2,)),
        n_clients=2, loss_fn=mlp_loss, acc_fn=mlp_loss, population=2,
    )
    ctx = phases.RoundContext(
        t=jnp.asarray(0), train_model=train_model, rng_fit=jax.random.PRNGKey(1),
        cohort_idx=jnp.arange(2), cohort_mask=jnp.ones((2,), bool),
    )
    out = trainer.fit(ctx, env)
    for leaf in jax.tree.leaves(out.trained):
        assert np.isfinite(np.asarray(leaf)).all()


def test_sgd_trainer_pad_trains_the_truncated_tail():
    x, y, m = _tiny_client_ds()
    g0 = init_mlp(jax.random.PRNGKey(0), 5, 3, hidden=(8,))
    train_model = jax.tree.map(lambda l: jnp.broadcast_to(l, (2,) + l.shape), g0)
    env = phases.RoundEnv(
        x_tr=jnp.asarray(x), y_tr=jnp.asarray(y), m_tr=jnp.asarray(m),
        x_te=jnp.asarray(x), y_te=jnp.asarray(y), m_te=jnp.asarray(m),
        n_samples=jnp.asarray(m.sum(1), jnp.float32), delay=jnp.ones((2,)),
        n_clients=2, loss_fn=mlp_loss, acc_fn=mlp_loss, population=2,
    )
    ctx = phases.RoundContext(
        t=jnp.asarray(0), train_model=train_model, rng_fit=jax.random.PRNGKey(1),
        cohort_idx=jnp.arange(2), cohort_mask=jnp.ones((2,), bool),
    )
    results = {}
    for remainder in ("drop", "pad"):
        trainer = phases.SGDTrainer(epochs=1, batch_size=32, lr=0.1, remainder=remainder)
        results[remainder] = trainer.fit(ctx, env).trained
    # the 3-sample client fits in batch 0 either way: its extra all-masked
    # tail batch must be a no-op (guarded masked loss), params identical
    for d, p in zip(jax.tree.leaves(results["drop"]), jax.tree.leaves(results["pad"])):
        np.testing.assert_array_equal(np.asarray(d)[0], np.asarray(p)[0])
    # the 40-sample client's dropped tail (slots 32..39) now trains: differs
    diffs = [
        np.abs(np.asarray(d)[1] - np.asarray(p)[1]).max()
        for d, p in zip(jax.tree.leaves(results["drop"]), jax.tree.leaves(results["pad"]))
    ]
    assert max(diffs) > 0.0
