"""Unit tests: layer sharing (K, DLD Eq. 9), personalization (Eq. 8),
aggregation (Eq. 1 + masked partial)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    compose_model,
    cut_model,
    dynamic_layer_definition,
    fedavg_aggregate,
    layer_share_mask,
    masked_partial_aggregate,
    num_layers,
    personalize_ft,
)
from repro.core.aggregation import transmitted_parameters
from repro.core.layersharing import layer_param_sizes, shared_param_count
from repro.models.mlp import init_mlp


def stacked_params(c=6, rng=jax.random.PRNGKey(0)):
    base = init_mlp(rng, 10, 4, hidden=(8, 8))
    return [
        jax.tree.map(
            lambda x, i=i: x[None] + jnp.arange(c, dtype=x.dtype).reshape((c,) + (1,) * x.ndim),
            layer,
        )
        for i, layer in enumerate(base)
    ], base


def test_dld_equation9_values():
    # PMS = 4 if A <= 0.25 else ceil(1/A)
    acc = jnp.asarray([0.0, 0.1, 0.25, 0.26, 0.5, 0.51, 0.9, 1.0])
    out = np.asarray(dynamic_layer_definition(acc, 4))
    assert list(out) == [4, 4, 4, 4, 2, 2, 2, 1]


def test_dld_clipped_to_total_layers():
    out = np.asarray(dynamic_layer_definition(jnp.asarray([0.26]), 3))
    assert out[0] == 3  # ceil(1/0.26)=4 clipped to 3


def test_cut_model_and_sizes():
    params = init_mlp(jax.random.PRNGKey(0), 561, 6)
    assert num_layers(params) == 4
    wg, wl = cut_model(params, 2)
    assert len(wg) == 2 and len(wl) == 2
    sizes = np.asarray(layer_param_sizes(params))
    assert sizes[0] == 561 * 256 + 256
    assert shared_param_count(params, 2) == int(sizes[:2].sum())
    with pytest.raises(ValueError):
        cut_model(params, 9)


def test_layer_share_mask_scalar_and_vector():
    m = np.asarray(layer_share_mask(4, jnp.asarray(2)))
    assert list(m) == [True, True, False, False]
    mv = np.asarray(layer_share_mask(3, jnp.asarray([0, 1, 3])))
    assert mv.shape == (3, 3)
    assert list(mv[2]) == [True, True, True]
    assert list(mv[0]) == [False, False, False]


def test_fedavg_aggregate_weighted_mean():
    stacked, base = stacked_params(c=4)
    sel = jnp.asarray([True, True, False, True])
    n = jnp.asarray([1.0, 2.0, 100.0, 1.0])
    agg = fedavg_aggregate(stacked[0], sel, n)
    # expected: weighted mean of clients 0,1,3 with w 1,2,1
    w = np.asarray([1, 2, 0, 1], np.float32)
    for key in ("w", "b"):
        x = np.asarray(stacked[0][key], np.float32)
        expect = (x * w.reshape(-1, *([1] * (x.ndim - 1)))).sum(0) / w.sum()
        np.testing.assert_allclose(np.asarray(agg[key]), expect, rtol=1e-5)


def test_masked_partial_aggregate_keeps_unshared():
    stacked, base = stacked_params(c=4)
    prev = jax.tree.map(lambda x: x * 0 - 7.0, base)
    sel = jnp.ones((4,), bool)
    n = jnp.ones((4,))
    share = layer_share_mask(3, jnp.asarray(1))  # only layer 0 shared
    out = masked_partial_aggregate(stacked, prev, sel, n, share)
    # layer 0 aggregated, layers 1-2 keep prev global (-7)
    assert not np.allclose(np.asarray(out[0]["w"]), -7.0)
    np.testing.assert_allclose(np.asarray(out[1]["w"]), -7.0)
    np.testing.assert_allclose(np.asarray(out[2]["w"]), -7.0)


def test_masked_partial_aggregate_ignores_unselected():
    stacked, base = stacked_params(c=4)
    prev = base
    n = jnp.ones((4,))
    share = layer_share_mask(3, jnp.asarray(3))
    sel_a = jnp.asarray([True, True, False, False])
    out_a = masked_partial_aggregate(stacked, prev, sel_a, n, share)
    # changing an unselected client's params must not change the result
    stacked_mod = jax.tree.map(lambda x: x.at[3].set(999.0), stacked)
    out_b = masked_partial_aggregate(stacked_mod, prev, sel_a, n, share)
    for a, b in zip(jax.tree.leaves(out_a), jax.tree.leaves(out_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_personalize_ft_eq8():
    stacked, base = stacked_params(c=3)
    loss_local = jnp.asarray([0.1, 5.0, 1.0])
    loss_global = jnp.asarray([1.0, 1.0, 1.0])
    out = personalize_ft(stacked, base, loss_local, loss_global)
    # client 0 keeps local, client 1 takes global, client 2 local (tie-ish <=)
    np.testing.assert_allclose(np.asarray(out[0]["w"][0]), np.asarray(stacked[0]["w"][0]))
    np.testing.assert_allclose(np.asarray(out[0]["w"][1]), np.asarray(base[0]["w"]))
    np.testing.assert_allclose(np.asarray(out[0]["w"][2]), np.asarray(stacked[0]["w"][2]))


def test_compose_model_mixes_layers():
    stacked, base = stacked_params(c=2)
    glob = jax.tree.map(lambda x: x * 0 + 3.0, base)
    share = jnp.asarray([[True, False, True], [False, False, False]])
    out = compose_model(glob, stacked, share)
    np.testing.assert_allclose(np.asarray(out[0]["w"][0]), 3.0)  # client0 layer0 global
    np.testing.assert_allclose(np.asarray(out[0]["w"][1]), np.asarray(stacked[0]["w"][1]))
    np.testing.assert_allclose(np.asarray(out[1]["w"][0]), np.asarray(stacked[1]["w"][0]))
    np.testing.assert_allclose(np.asarray(out[2]["w"][0]), 3.0)


def test_transmitted_parameters_accounting():
    params = init_mlp(jax.random.PRNGKey(0), 10, 4, hidden=(8, 8))
    sizes = layer_param_sizes(params)
    sel = jnp.asarray([True, False, True])
    share = layer_share_mask(3, jnp.asarray([3, 3, 1]))
    tx = float(transmitted_parameters(sel, share, sizes))
    expect = float(sizes[:3].sum()) + float(sizes[0])  # client0 all, client2 first layer
    assert tx == pytest.approx(expect)
