"""Checkpoint/resume: a run interrupted at round t and resumed from its
snapshot must be bitwise-identical to the uninterrupted run — on every
FLHistory lane, across both schedulers, both population placements, the
stateful-FT and lossy-int8 golden configs, and the memmap-backed
``PopulationStore``."""

import os

import numpy as np
import pytest

from repro.data import make_federated_classification
from repro.fl import FLConfig, run_federated
from repro.fl.population import run_host_sync
from repro.fl.sched import resolve_checkpoint_dir


@pytest.fixture(scope="module")
def small_ds():
    return make_federated_classification(
        n_clients=8, n_classes=4, n_features=20,
        samples_per_client_range=(60, 90), dirichlet_alpha=50.0,
        client_shift=0.05, class_sep=5.0, seed=1,
    )


# the four committed golden configs (tests/test_fl_api.py::_GOLDEN)
_GOLDEN_CFGS = {
    "acsp-fl+dld+float32": dict(),
    "fedavg+none+float32": dict(strategy="fedavg", personalization="none",
                                fraction=1.0),
    "oort+ft+float32": dict(strategy="oort", personalization="ft",
                            fraction=0.5),
    "acsp-fl+dld+int8": dict(codec="int8"),
}


def _assert_history_equal(h_full, h_res):
    for field in h_full._fields:
        a, b = getattr(h_full, field), getattr(h_res, field)
        if a is None and b is None:
            continue
        assert a is not None and b is not None, field
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=field)


def _interrupt_and_resume(ds, cfg_kw, ckpt_dir, stop_at=2, rounds=5):
    """Run to ``stop_at`` with checkpointing, then resume to ``rounds``."""
    run_federated(ds, FLConfig(rounds=stop_at, epochs=1, **cfg_kw),
                  checkpoint_every=stop_at, checkpoint_dir=ckpt_dir)
    return run_federated(ds, FLConfig(rounds=rounds, epochs=1, **cfg_kw),
                         resume_from=ckpt_dir)


@pytest.mark.parametrize("name", sorted(_GOLDEN_CFGS))
def test_sync_resume_bitwise_on_goldens(small_ds, tmp_path, name):
    cfg_kw = _GOLDEN_CFGS[name]
    h_full = run_federated(small_ds, FLConfig(rounds=5, epochs=1, **cfg_kw))
    h_res = _interrupt_and_resume(small_ds, cfg_kw, str(tmp_path / "ckpt"))
    _assert_history_equal(h_full, h_res)


@pytest.mark.parametrize("name", ["oort+ft+float32", "acsp-fl+dld+int8"])
def test_async_resume_bitwise(small_ds, tmp_path, name):
    # stateful FT and lossy int8 under the event-driven scheduler: the
    # snapshot must carry the EF residuals, slot plane, and event queue
    cfg_kw = dict(_GOLDEN_CFGS[name], scheduler="async", buffer_k=2,
                  max_concurrency=4)
    h_full = run_federated(small_ds, FLConfig(rounds=5, epochs=1, **cfg_kw))
    h_res = _interrupt_and_resume(small_ds, cfg_kw, str(tmp_path / "ckpt"))
    _assert_history_equal(h_full, h_res)


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_host_population_resume_bitwise(small_ds, tmp_path, mode):
    cfg_kw = dict(host_population=1)
    if mode == "async":
        cfg_kw.update(scheduler="async", buffer_k=2, max_concurrency=4)
    h_full = run_federated(small_ds, FLConfig(rounds=5, epochs=1, **cfg_kw))
    h_res = _interrupt_and_resume(small_ds, cfg_kw, str(tmp_path / "ckpt"))
    _assert_history_equal(h_full, h_res)


def test_memmap_store_resume_bitwise(small_ds, tmp_path):
    # the interrupted and resumed runs each get their own memmap backing;
    # the snapshot (not the stale backing files) must carry the state
    cfg_kw = dict(strategy="oort", personalization="ft", fraction=0.5,
                  codec="int8", host_population=1)
    ckpt = str(tmp_path / "ckpt")
    h_full = run_host_sync(
        small_ds, FLConfig(rounds=5, epochs=1, **cfg_kw),
        backing_dir=str(tmp_path / "pop_full"),
    )
    run_host_sync(
        small_ds, FLConfig(rounds=2, epochs=1, **cfg_kw),
        backing_dir=str(tmp_path / "pop_a"),
        checkpoint_every=2, checkpoint_dir=ckpt,
    )
    h_res = run_host_sync(
        small_ds, FLConfig(rounds=5, epochs=1, **cfg_kw),
        backing_dir=str(tmp_path / "pop_b"), resume_from=ckpt,
    )
    _assert_history_equal(h_full, h_res)
    # the resumed run's backing slabs were rehydrated and written through
    assert any(n.startswith("local_") for n in os.listdir(str(tmp_path / "pop_b")))


def test_resume_with_faults_bitwise(small_ds, tmp_path):
    # fault plans are a pure function of (config, seed, round), so resuming
    # mid-run replays the exact same crash/corruption schedule
    cfg_kw = dict(dropout_rate=0.3, deadline_s=10.0, corrupt_rate=0.2)
    h_full = run_federated(small_ds, FLConfig(rounds=5, epochs=1, **cfg_kw))
    h_res = _interrupt_and_resume(small_ds, cfg_kw, str(tmp_path / "ckpt"))
    _assert_history_equal(h_full, h_res)


def test_resume_from_doubles_as_write_dir(small_ds, tmp_path):
    # an interrupted run resumed with only resume_from keeps checkpointing
    # into the same directory
    d = str(tmp_path / "ckpt")
    run_federated(small_ds, FLConfig(rounds=2, epochs=1),
                  checkpoint_every=2, checkpoint_dir=d)
    run_federated(small_ds, FLConfig(rounds=4, epochs=1),
                  checkpoint_every=2, resume_from=d)
    rounds = sorted(
        fn for fn in os.listdir(d) if fn.endswith("_meta.json")
    )
    assert rounds == ["round_00002_meta.json", "round_00004_meta.json"]


def test_checkpoint_every_requires_dir():
    with pytest.raises(ValueError, match="checkpoint"):
        resolve_checkpoint_dir(2, None, None)
    assert resolve_checkpoint_dir(0, None, None) is None
    assert resolve_checkpoint_dir(2, "/tmp/x", None) == "/tmp/x"
    assert resolve_checkpoint_dir(2, None, "/tmp/y") == "/tmp/y"
