"""Extra layer-level coverage: MLA absorbed decode, GQA decode-vs-train
consistency, MoE routing invariants, RoPE variants, sliding-window ring
buffer."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.models import layers as L


def test_mla_absorbed_decode_matches_naive():
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    p = L.init_mla(jax.random.PRNGKey(0), cfg)
    x_ctx = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)).astype(jnp.bfloat16)
    _, cache = L.mla_attention(p, x_ctx, jnp.arange(16), cfg, mode="prefill")
    x_new = jax.random.normal(jax.random.PRNGKey(2), (2, 1, cfg.d_model)).astype(jnp.bfloat16)
    pos = jnp.asarray(16)

    os.environ["REPRO_MLA_DECODE"] = "naive"
    out_n, _ = L.mla_attention(p, x_new, pos, cfg, cache=cache, mode="decode")
    os.environ["REPRO_MLA_DECODE"] = "absorbed"
    out_a, _ = L.mla_attention(p, x_new, pos, cfg, cache=cache, mode="decode")
    os.environ.pop("REPRO_MLA_DECODE")
    np.testing.assert_allclose(
        np.asarray(out_n, np.float32), np.asarray(out_a, np.float32), atol=3e-2, rtol=3e-2
    )


def test_gqa_decode_matches_train_prefix():
    """Autoregressive decode must reproduce the train-mode attention outputs."""
    cfg = get_config("granite-3-8b").reduced()
    p = L.init_gqa(jax.random.PRNGKey(0), cfg)
    s = 12
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, cfg.d_model)).astype(jnp.bfloat16)
    y_train, _ = L.gqa_attention(p, x, jnp.arange(s), cfg, mode="train")
    cache = L.init_gqa_cache(cfg, 2, s)
    outs = []
    for t in range(s):
        y_t, cache = L.gqa_attention(p, x[:, t : t + 1], jnp.asarray(t), cfg, cache=cache, mode="decode")
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_dec, np.float32), np.asarray(y_train, np.float32), atol=3e-2, rtol=3e-2
    )


def test_moe_capacity_and_gates():
    cfg = get_config("deepseek-moe-16b").reduced()
    p = L.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model)).astype(jnp.bfloat16)
    y, aux = L.moe_apply_local(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(aux)) and float(aux) > 0.5  # ~E * uniform = 1
    # aux loss near 1 for near-uniform routing at init
    assert float(aux) < float(cfg.n_experts)


def test_moe_zero_capacity_factor_drops_everything():
    import dataclasses

    cfg = dataclasses.replace(get_config("deepseek-moe-16b").reduced(), capacity_factor=1e-9, n_shared_experts=0)
    p = L.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model)).astype(jnp.bfloat16)
    y, _ = L.moe_apply_local(p, x, cfg)
    # capacity 1 per expert: most tokens dropped; output bounded, finite
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))


def test_rope_variants_shapes_and_phase():
    cfg_full = get_config("granite-3-8b").reduced()
    cfg_half = get_config("chatglm3-6b").reduced()
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 4, 64))
    pos = jnp.arange(8)[None].repeat(1, 0)
    full = L.apply_rope(x, pos, cfg_full)
    half = L.apply_rope(x, pos, cfg_half)
    assert full.shape == half.shape == x.shape
    # half-rope leaves the top half of head dims untouched
    np.testing.assert_array_equal(np.asarray(half[..., 32:]), np.asarray(x[..., 32:]))
    assert not np.allclose(np.asarray(full[..., 32:]), np.asarray(x[..., 32:]))
    # position 0 is identity in both
    np.testing.assert_allclose(np.asarray(full[0, 0]), np.asarray(x[0, 0]), rtol=1e-5)


def test_mrope_sections_match_linear_for_text():
    """For text tokens (t=h=w=pos), M-RoPE must equal standard RoPE."""
    cfg = get_config("qwen2-vl-2b").reduced()
    import dataclasses

    cfg_std = dataclasses.replace(cfg, rope_variant="full")
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 2, cfg.head_dim_))
    lin = jnp.arange(6, dtype=jnp.int32)
    pos3 = jnp.broadcast_to(lin[None, :, None], (1, 6, 3))
    a = L.apply_rope(x, pos3, cfg)
    b = L.apply_rope(x, lin[None].repeat(1, 0), cfg_std)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_sliding_window_ring_buffer_eviction():
    """Decode-from-scratch ring buffer: positions older than the window are
    masked; the buffer wraps without corrupting newer entries."""
    cfg = get_config("granite-3-8b").reduced()
    p = L.init_gqa(jax.random.PRNGKey(0), cfg)
    w = 4
    cache = L.init_gqa_cache(cfg, 1, 64, window=w)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 10, cfg.d_model)).astype(jnp.bfloat16)
    for t in range(10):
        _, cache = L.gqa_attention(p, x[:, t : t + 1], jnp.asarray(t), cfg, cache=cache, window=w, mode="decode")
    kv_pos = np.asarray(cache["kv_pos"])
    assert sorted(kv_pos.tolist()) == [6, 7, 8, 9]  # only the last w positions


def test_chunked_attention_chunk_invariance():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 50, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 50, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 50, 2, 32))
    pos = jnp.arange(50)
    a = L.chunked_attention(q, k, v, pos, pos, chunk=16)
    b = L.chunked_attention(q, k, v, pos, pos, chunk=50)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)
