"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, masked_aggregate, ssm_scan
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.masked_aggregate.ref import masked_aggregate_ref
from repro.kernels.ssm_scan.ref import ssm_scan_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_SHAPES = [
    # (b, s, h, hkv, d, causal, window)
    (2, 128, 4, 2, 64, True, 0),
    (1, 256, 8, 8, 32, True, 0),
    (2, 192, 4, 1, 64, True, 64),     # GQA + sliding window, ragged seq
    (1, 96, 2, 2, 128, False, 0),     # bidirectional (whisper encoder)
    (1, 64, 6, 3, 64, True, 0),
]


@pytest.mark.parametrize("b,s,h,hkv,d,causal,window", FA_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, s, h, hkv, d, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(hash((b, s, h, d)) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal, window=window,
    ).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_matches_model_attention():
    """Kernel vs the model's chunked_attention (two independent oracles)."""
    from repro.models.layers import chunked_attention

    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    b, s, h, hkv, d = 2, 160, 4, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    pos = jnp.arange(s)
    a = chunked_attention(q, k, v, pos, pos, causal=True, chunk=64)
    bout = flash_attention(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bout), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# masked aggregate
# ---------------------------------------------------------------------------

AGG_SHAPES = [(1, 7), (30, 1000), (60, 513), (4, 8192)]


@pytest.mark.parametrize("c,p", AGG_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_aggregate_matches_ref(c, p, dtype):
    ks = jax.random.split(jax.random.PRNGKey(c * p), 3)
    x = jax.random.normal(ks[0], (c, p), jnp.float32).astype(dtype)
    w = jnp.where(jax.random.uniform(ks[1], (c,)) > 0.4, jax.random.uniform(ks[2], (c,)) * 50, 0.0)
    fb = jnp.zeros((p,), dtype)
    out = masked_aggregate(x, w, fb, interpret=True)
    ref = masked_aggregate_ref(x, w, fb)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_masked_aggregate_zero_weights_falls_back():
    x = jnp.ones((5, 64))
    fb = jnp.full((64,), 3.5)
    out = masked_aggregate(x, jnp.zeros((5,)), fb, interpret=True)
    np.testing.assert_allclose(np.asarray(out), 3.5)


def test_masked_aggregate_nd_leaf():
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 9, 11))
    w = jnp.asarray([1.0, 0, 2, 0, 3, 0])
    fb = jnp.zeros((9, 11))
    out = masked_aggregate(x, w, fb, interpret=True)
    ref = masked_aggregate_ref(x.reshape(6, -1), w, fb.reshape(-1)).reshape(9, 11)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------

SSM_SHAPES = [(1, 64, 16, 8), (2, 100, 32, 8), (1, 256, 64, 16), (3, 33, 8, 4)]


@pytest.mark.parametrize("b,s,di,ds", SSM_SHAPES)
def test_ssm_scan_matches_ref(b, s, di, ds):
    ks = jax.random.split(jax.random.PRNGKey(b * s + di), 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, s, di))) * 0.1
    a = -jnp.exp(jax.random.normal(ks[1], (di, ds)) * 0.3)
    bm = jax.random.normal(ks[2], (b, s, ds))
    cm = jax.random.normal(ks[3], (b, s, ds))
    x = jax.random.normal(ks[4], (b, s, di))
    d = jnp.ones((di,))
    y, h = ssm_scan(dt, a, bm, cm, x, d, chunk=32, interpret=True)
    yr, hr = ssm_scan_ref(dt, a, bm, cm, x, d)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=2e-5, rtol=2e-5)


def test_ssm_scan_chunk_invariance():
    b, s, di, ds = 1, 96, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, s, di))) * 0.1
    a = -jnp.exp(jax.random.normal(ks[1], (di, ds)) * 0.3)
    bm = jax.random.normal(ks[2], (b, s, ds))
    cm = jax.random.normal(ks[3], (b, s, ds))
    x = jax.random.normal(ks[4], (b, s, di))
    d = jnp.zeros((di,))
    y32, _ = ssm_scan(dt, a, bm, cm, x, d, chunk=32, interpret=True)
    y96, _ = ssm_scan(dt, a, bm, cm, x, d, chunk=96, interpret=True)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y96), atol=1e-5, rtol=1e-5)


def test_ssm_scan_matches_mamba_block_path():
    """Kernel vs the mamba_block jnp scan through the model-layer lens."""
    from repro.configs import get_config
    from repro.models import layers as L

    cfg = get_config("falcon-mamba-7b").reduced()
    p = L.init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 40, cfg.d_model)).astype(jnp.bfloat16)
    y_block, _ = L.mamba_block(p, x, cfg, mode="train")

    # re-derive the scan inputs exactly as mamba_block does
    di, ds, dtr = cfg.d_inner, cfg.d_state, cfg.dt_rank_
    u = x @ p["in_proj"]
    xs, z = u[..., :di], u[..., di:]
    xs, _ = L._causal_conv(xs, p["conv_w"], p["conv_b"])
    xs = L.silu(xs)
    xdb = xs @ p["x_proj"]
    dt_raw, bm, cm = jnp.split(xdb, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    y_k, _ = ssm_scan(dt, a, bm.astype(jnp.float32), cm.astype(jnp.float32), xs.astype(jnp.float32), p["D"], chunk=16, interpret=True)
    out_k = (y_k.astype(x.dtype) * L.silu(z)) @ p["out_proj"]
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(y_block, np.float32), atol=5e-2, rtol=5e-2
    )
